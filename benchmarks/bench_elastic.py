"""Elastic resize cost (docs/elasticity.md): incremental reshard of the
cached partitions vs the cold alternative — dropping the cache and
recomputing it from lineage at the new world size.

Two timed arms over one pipeline — an expensive persisted map of
``blocks=8`` (64 chained transcendentals per element, so recomputing a
block costs real FLOPs while moving it is one device_put):

  * **incremental**: ``shrink(2)`` + action, ``grow(2)`` + action — the
    resize re-pads and re-places the cached blocks (``reshard_moves``),
    zero lineage evaluation;
  * **cold**: the cached map is dropped before each resize — what
    elasticity would cost without the incremental reshard (every block
    recomputed from the source at the new world size).

The derived factor is a per-iteration-interleaved ratio median (machine
drift cancels, same protocol as bench_recovery):

  * ``reshard_vs_cold`` (target ≥ 0.6) — a catastrophic-regression floor
    only: moving cached blocks must not become slower than recomputing
    them. At smoke sizes the arms are tens-of-ms quantities on shared
    runners, so a tight floor would gate noise; the conformance tier
    (tests/test_elastic.py) owns the EXACT ``recomputes == 0`` guarantee.

The ``retries=``/``recompiles=`` counters in derived are the TIGHT gate
(tools/check_bench.py): a resize that starts overflowing shuffles or
recompiling plans regressed regardless of hardware.

Needs 8 devices, so ``bench()`` re-executes this file in a subprocess with
``--xla_force_host_platform_device_count=8`` (the flag must never leak into
the caller — same isolation rule as tests/test_elastic.py).
"""
from __future__ import annotations

import os
import subprocess
import sys


def _child(n: int, iters: int) -> list:
    import time

    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import row
    from repro.core import ICluster, IProperties, IWorker

    w = IWorker(ICluster(IProperties({"ignis.executor.instances": "8"})), "python")
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 2**31 - 1, n).astype(np.int32)

    def heavy(x):
        y = x.astype(jnp.float32) * jnp.float32(1e-9)
        for _ in range(64):
            y = jnp.sin(y) * jnp.float32(1.0001) + jnp.float32(0.1)
        return (y * jnp.float32(1000)).astype(jnp.int32)

    frame = w.parallelize(vals, blocks=8).map(heavy).persist()
    oracle = frame.count()

    def action():
        assert frame.count() == oracle

    def resize_pair(drop: bool) -> float:
        t0 = time.perf_counter()
        for step in ("shrink", "grow"):
            if drop:
                frame.node.result = None  # cold: no cache to reshard
            (w.shrink if step == "shrink" else w.grow)(2)
            action()
        return time.perf_counter() - t0

    # warm: compile the map at every capacity the resize cycle visits
    # (capacity padding is monotonic and stabilises after one pair)
    resize_pair(False)
    resize_pair(True)

    t_inc, t_cold, ratio = [], [], []
    for _ in range(iters):
        ti = resize_pair(False)
        tc = resize_pair(True)
        t_inc.append(ti)
        t_cold.append(tc)
        ratio.append(tc / ti)

    med = lambda xs: sorted(xs)[len(xs) // 2]  # noqa: E731
    st = w.shuffle_stats()
    el = w.metrics("elastic")
    return [
        row("elastic_incremental", med(t_inc),
            f"n={n} blocks=8 resize=shrink2+grow2 "
            f"moves={el['reshard_moves']}"),
        row("elastic_cold", med(t_cold),
            "cache dropped before each resize: every block re-evaluated "
            "from lineage at the new world size"),
        row("elastic_reshard", 0.0,
            f"reshard_vs_cold={med(ratio):.2f}x target=0.6 "
            f"retries={st['overflow_retries']} "
            f"recompiles={st['wide_plan_misses']}"),
        row("elastic_integrity", 0.0,
            f"reshard_recomputes={el['reshard_recomputes']} "
            f"grows={el['grows']} shrinks={el['shrinks']} "
            f"world={w.executors}"),
    ]


def bench(n: int = 200_000, iters: int = 5) -> list:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root, env.get("PYTHONPATH", "")])
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child", str(n), str(iters)],
        env=env, capture_output=True, text=True, timeout=1200, cwd=root,
    )
    if r.returncode != 0:
        raise RuntimeError(f"bench_elastic child failed:\n{r.stderr[-2000:]}")
    rows = [ln[len("ROW "):] for ln in r.stdout.splitlines()
            if ln.startswith("ROW ")]
    if not rows:
        raise RuntimeError(f"bench_elastic child emitted no rows:\n{r.stdout}")
    return rows


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        n, iters = (int(x) for x in sys.argv[2:4])
        for r in _child(n, iters):
            print(f"ROW {r}")
    else:
        from benchmarks.common import emit

        emit(bench())
