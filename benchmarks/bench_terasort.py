"""TeraSort (paper Fig. 15): PSRS distributed sort throughput, ignis vs
spark mode (host pipe on the pre-sort map)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core import ICluster, IProperties, IWorker


def _sort(worker, keys):
    return worker.parallelize(keys).map(lambda x: x).sort().count()


def bench(n: int = 200_000):
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**31 - 1, n).astype(np.int32)
    rows = []
    res = {}
    for mode in ("ignis", "spark"):
        w = IWorker(ICluster(IProperties({"ignis.mode": mode})), "python")
        t = timeit(lambda: _sort(w, keys), warmup=1, iters=3)
        res[mode] = t
        rows.append(row(f"terasort_{mode}", t, f"Mkeys/s={n/t/1e6:.2f}"))
    rows.append(row("terasort_speedup", 0.0,
                    f"ignis_vs_spark={res['spark']/res['ignis']:.2f}x"))
    return rows
