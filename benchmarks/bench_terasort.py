"""TeraSort (paper Fig. 15): PSRS distributed sort throughput, ignis vs
spark mode (host pipe on the pre-sort map).

Also reports the adaptive shuffle engine's telemetry (DESIGN.md §6): the
timing loop re-builds the pipeline every iteration, so overflow retries,
wide-stage recompiles and capacity-memory hits show whether repeated sorts
ran capacity-warm (they should: retries=0 after the first action, memory
hits growing, compiles flat)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core import ICluster, IProperties, IWorker


def _sort(worker, keys):
    return worker.parallelize(keys).map(lambda x: x).sort().count()


def bench(n: int = 200_000):
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**31 - 1, n).astype(np.int32)
    rows = []
    res = {}
    for mode in ("ignis", "spark"):
        w = IWorker(ICluster(IProperties({"ignis.mode": mode})), "python")
        t = timeit(lambda: _sort(w, keys), warmup=1, iters=3)
        st = w.shuffle_stats()
        res[mode] = t
        rows.append(row(
            f"terasort_{mode}", t,
            f"Mkeys/s={n/t/1e6:.2f} retries={st['overflow_retries']} "
            f"recompiles={st['wide_plan_misses']} "
            f"mem_hits={st['capacity_memory_hits']}"))
    # no target= here: the spark arm is GIL-bound while the ignis arm is
    # device-bound, so this ratio tracks machine load (observed 1.6x-7.9x)
    # — declaring it stable would make the tools/check_bench.py gate flaky;
    # the retries/recompiles counters above are terasort's stable gate
    rows.append(row("terasort_speedup", 0.0,
                    f"ignis_vs_spark={res['spark']/res['ignis']:.2f}x"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(bench())
