"""Cost-model benchmarks (DESIGN.md §13, docs/profiling.md): replay
accuracy against a real 8-device gang-scheduled trace, a deterministic
what-if replay, and the cost-aware fusion policy against the static one.

Three claims, three rows:

  * **cost_replay_accuracy** — a two-gang job runs on an 8-device mesh
    under an attached ``JobTracer``; the capture replays under the
    identity hypothesis and the predicted makespan must land within 25%
    of the measured one (``replay_accuracy`` factor, ``target=0.75``).
    The same child validates the exported Chrome trace against the span
    schema (``validate()``) and writes it as the CI timeline artifact.
  * **cost_whatif_replay** — the same capture replayed under
    ``Hypothesis(lanes=1)``: consolidating the two gang lanes onto one
    must predict a LONGER makespan (the simulator respects lane
    serialisation), and two runs of the same simulation must produce the
    identical schedule — determinism is asserted in the child.
  * **cost_vs_static_fusion** — the shape-churn regime the static policy
    handles badly: batches of 3-op narrow chains whose stage signatures
    NEVER repeat (fresh op permutations from a fixed warm library).
    Static fuses every chain and pays an XLA compile per signature for
    dispatch savings it never banks; the cost policy defers first
    sightings and runs the warm per-op kernels. Interleaved per-iteration
    ratios (static wall / cost wall), median reported — the same
    drift-defence as bench_groups. On a repeated signature both arms
    converge (cost fuses from the second sighting); reported as the
    ungated ``repeat_ratio``.

The replay rows need 8 devices, so ``bench()`` re-executes this file in a
subprocess with ``--xla_force_host_platform_device_count=8`` (the same
isolation rule as bench_groups — the flag must never leak into the
caller).
"""
from __future__ import annotations

import itertools
import os
import subprocess
import sys
import time


# fixed op library: module-level defs, stable code objects, so every op's
# vmapped kernel jits ONCE (executor._VMAP_JIT) while 3-op permutations
# give C(6,3)·3! = 120 distinct never-repeating stage signatures
def _op_add(x):
    return x + 1


def _op_mul(x):
    return x * 2


def _op_sub(x):
    return x - 3


def _op_xor(x):
    return x ^ 5


def _op_sq(x):
    return x * x


def _op_neg(x):
    return -x


_OPS = (_op_add, _op_mul, _op_sub, _op_xor, _op_sq, _op_neg)


# ---------------------------------------------------------------------------
# replay accuracy + what-if (8-device child)
# ---------------------------------------------------------------------------


def _child(n: int, gang_actions: int, trace_out: str) -> list:
    import numpy as np
    import jax.numpy as jnp

    from benchmarks.common import row
    from repro.core import ICluster, IProperties, IWorker
    from repro.core.job import IJob
    from repro.profile import (Hypothesis, JobTracer, capture,
                               predicted_vs_measured, simulate, validate)

    cluster = ICluster(IProperties({"ignis.executor.instances": "8"}))
    w = IWorker(cluster, "spmd")
    g0, g1 = w.groups(2)

    rng = np.random.default_rng(0)
    vals = rng.integers(0, 100_000, n).astype(np.int32)

    def submit(job):
        futs = []
        for _ in range(gang_actions):
            df = w.parallelize(vals).map(lambda x: x * 2 + 1)
            futs.append(df.count_async(job=job))
            kv = w.parallelize(vals).map(
                lambda x: {"key": x % 53, "value": jnp.int32(1)})
            futs.append(kv.reduce_by_key(lambda a, b: a + b, 0)
                        .count_async(job=job))
        return futs

    # warm-up: compile both gang widths before the measured capture, so the
    # trace measures steady-state scheduling, not first-touch XLA compiles
    for f in submit(IJob("warmA", group=g0)) + submit(IJob("warmB", group=g1)):
        f.result(600)

    tracer = JobTracer()
    tracer.attach_worker(w)
    job = IJob("gangpair", gang=2)  # deals tasks round-robin over 2 groups
    tracer.attach(job)
    t0 = time.perf_counter()
    for f in submit(job):
        f.result(600)
    wall = time.perf_counter() - t0

    r = predicted_vs_measured(job)
    trace = capture(job)

    # what-if: both gang lanes consolidated onto one — strictly less
    # parallelism, so the simulator must predict a makespan no shorter
    # than identity, and identically twice (determinism)
    ident = simulate(trace)
    s1 = simulate(trace, Hypothesis(lanes=1))
    s2 = simulate(trace, Hypothesis(lanes=1))
    assert s1 == s2, "what-if replay is not deterministic"
    assert s1.makespan_s >= ident.makespan_s * 0.999, (
        s1.makespan_s, ident.makespan_s)

    chrome = tracer.to_chrome()
    violations = validate(chrome)
    assert not violations, violations[:5]
    if trace_out:
        tracer.save(trace_out)

    return [
        row("cost_replay_accuracy", wall,
            f"replay_accuracy={r['accuracy']:.2f}x target=0.75 "
            f"tasks={r['tasks']} lanes={r['lanes']} "
            f"schema_violations={len(violations)} world=8"),
        row("cost_whatif_replay", s1.makespan_s,
            f"whatif_lanes1_vs_identity={s1.makespan_s / max(ident.makespan_s, 1e-9):.2f}x "
            f"identity_ms={ident.makespan_s * 1e3:.1f} deterministic=1"),
    ]


# ---------------------------------------------------------------------------
# cost-aware vs static fusion (in-process)
# ---------------------------------------------------------------------------


def _fusion_rows(n: int, chains: int, iters: int) -> list:
    import numpy as np

    from benchmarks.common import row
    from repro.core import ICluster, IProperties, IWorker

    def make_worker(mode):
        cl = ICluster(IProperties({"ignis.fusion.mode": mode}))
        return IWorker(cl, "python")

    w_static = make_worker("static")
    w_cost = make_worker("cost")
    data = np.arange(n, dtype=np.int32)

    def run_batch(w, batch):
        total = 0
        for ops in batch:
            df = w.parallelize(data)
            for f in ops:
                df = df.map(f)
            total += int(df.reduce(lambda a, b: a + b))
        return total

    # warm the per-op kernel jits (global executor cache, shared by both
    # arms) with single-op runs — single ops never fuse in either mode
    for f in _OPS:
        run_batch(w_static, [(f,)])
        run_batch(w_cost, [(f,)])

    # fresh 3-op signatures per iteration, same batch fed to BOTH arms
    # within the iteration (interleaved; median of per-iteration ratios)
    perms = itertools.permutations(_OPS, 3)
    ts, tc, ratios = [], [], []
    for _ in range(iters):
        batch = list(itertools.islice(perms, chains))
        assert len(batch) == chains, "op library exhausted; shrink iters*chains"
        t0 = time.perf_counter()
        r_static = run_batch(w_static, batch)
        t1 = time.perf_counter()
        r_cost = run_batch(w_cost, batch)
        t2 = time.perf_counter()
        assert r_static == r_cost, (r_static, r_cost)  # correctness parity
        ts.append(t1 - t0)
        tc.append(t2 - t1)
        ratios.append((t1 - t0) / (t2 - t1))

    t_static = sorted(ts)[len(ts) // 2]
    t_cost = sorted(tc)[len(tc) // 2]
    speedup = sorted(ratios)[len(ratios) // 2]

    # repeated-signature regime: one fixed chain run twice per arm — the
    # cost policy fuses from the second sighting, so the arms converge
    fixed = (_op_add, _op_mul, _op_sub)
    for w in (w_static, w_cost):
        run_batch(w, [fixed])
    t0 = time.perf_counter()
    run_batch(w_static, [fixed])
    t1 = time.perf_counter()
    run_batch(w_cost, [fixed])
    t2 = time.perf_counter()
    repeat_ratio = (t1 - t0) / max(t2 - t1, 1e-9)

    est = w_static.engine.stats
    ecc = w_cost.engine.stats
    cost_snap = w_cost.engine.cost_model.snapshot()
    return [
        row("cost_fusion_static_arm", t_static,
            f"chains={chains} n={n} fused={est['fused_stages']}"),
        row("cost_fusion_cost_arm", t_cost,
            f"deferred={ecc['fusion_deferred']} fused={ecc['fused_stages']} "
            f"decisions={cost_snap['fuse_decisions']}"),
        row("cost_vs_static_fusion", 0.0,
            f"cost_vs_static={speedup:.2f}x target=1.2 "
            f"repeat_ratio={repeat_ratio:.2f} chains={chains} iters={iters}"),
    ]


def bench(n: int = 1 << 12, chains: int = 10, iters: int = 5,
          gang_actions: int = 6, trace_out: str | None = None) -> list:
    if trace_out is None:
        trace_out = os.environ.get("IGNIS_TRACE_OUT",
                                   "bench-trace-cost-model.json")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root, env.get("PYTHONPATH", "")])
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child", str(n),
         str(gang_actions), os.path.abspath(trace_out)],
        env=env, capture_output=True, text=True, timeout=1200, cwd=root,
    )
    if r.returncode != 0:
        raise RuntimeError(f"bench_cost_model child failed:\n{r.stderr[-2000:]}")
    rows = [ln[len("ROW "):] for ln in r.stdout.splitlines()
            if ln.startswith("ROW ")]
    if not rows:
        raise RuntimeError(f"bench_cost_model child emitted no rows:\n{r.stdout}")
    return rows + _fusion_rows(n, chains, iters)


if __name__ == "__main__":
    if sys.argv[1:2] == ["--child"]:
        n, gang_actions = int(sys.argv[2]), int(sys.argv[3])
        for out_row in _child(n, gang_actions, sys.argv[4]):
            print(f"ROW {out_row}")
    else:
        from benchmarks.common import emit

        emit(bench())
