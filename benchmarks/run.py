"""Benchmark harness — one entry per paper table/figure.

  §3.5       bench_fusion      stage compilation: fused vs per-op dispatch
  Fig 13/14  bench_minebench   chained maps, ignis vs spark, multi-worker
  Fig 15     bench_terasort    PSRS distributed sort
  Fig 16     bench_kmeans      iterative: fused loop vs driver evaluation
  Fig 17     bench_pagerank    join/reduceByKey graph pattern
  Fig 18     bench_tc          join/union/distinct fixed point
  Fig 19-22  bench_hpc_native  native SPMD apps via worker.call (overhead %)
  §3.2/Fig 2 bench_hybrid      one IJob: native + MapReduce branches overlap
  §4 (UCC)   bench_collectives blocking vs nonblocking vs persistent plans
  §11 (ours) bench_kernels     Pallas kernel tier vs jnp oracles, wide stages
  §2.2/§5    bench_groups      gang-scheduled jobs on disjoint sub-meshes
  §12 (ours) bench_streaming   multi-tenant micro-batch pumps vs sequential
  §13 (ours) bench_cost_model  replay accuracy on a gang trace, what-if
                               replay, cost-aware vs static fusion
  §14 (ours) bench_elastic     resize cost: incremental reshard vs cold
                               recompute of the cached partitions
  Table 5    bench_sloc        integration SLOC
  (ours)     roofline          §Roofline summary from the dry-run artifacts

Prints ``name,us_per_call,derived`` CSV. ``--only <name>`` to subset;
``--smoke`` shrinks problem sizes for CI; ``--json PATH`` additionally
writes the rows as a JSON artifact (one record per row) so the per-PR perf
trajectory is machine-readable.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks.common import emit

# reduced problem sizes for the CI perf-smoke job (fast, still exercises the
# shuffle/fusion paths end to end)
SMOKE_KWARGS = {
    "fusion": {"n": 1 << 12, "blocks": 4, "iters": 3},
    "terasort": {"n": 20_000},
    "pagerank": {"n_vertices": 24, "n_edges": 60, "iters": 2},
    "kmeans": {},
    "minebench": {},
    "hybrid": {"n": 1 << 14, "cg_iters": 400, "iters": 3, "n_cg": 1 << 16},
    "collectives": {"n": 1 << 10, "iters": 10},
    "kernels": {"n": 20_000, "iters": 3},
    "groups": {"size": 2048, "cg_iters": 1000, "n": 1 << 10, "iters": 3},
    "recovery": {"n": 20_000, "iters": 3},
    "elastic": {"n": 20_000, "iters": 3},
    "streaming": {"tenants": 4, "batches": 24, "rows_per_batch": 16,
                  "iters": 2},
    "cost_model": {"n": 1 << 10, "chains": 4, "iters": 2, "gang_actions": 4},
}

BENCHES = [
    ("fusion", "benchmarks.bench_fusion"),
    ("minebench", "benchmarks.bench_minebench"),
    ("terasort", "benchmarks.bench_terasort"),
    ("kmeans", "benchmarks.bench_kmeans"),
    ("pagerank", "benchmarks.bench_pagerank"),
    ("tc", "benchmarks.bench_tc"),
    ("hpc_native", "benchmarks.bench_hpc_native"),
    ("hybrid", "benchmarks.bench_hybrid"),
    ("collectives", "benchmarks.bench_collectives"),
    ("kernels", "benchmarks.bench_kernels"),
    ("groups", "benchmarks.bench_groups"),
    ("streaming", "benchmarks.bench_streaming"),
    ("cost_model", "benchmarks.bench_cost_model"),
    ("recovery", "benchmarks.bench_recovery"),
    ("elastic", "benchmarks.bench_elastic"),
    ("sloc", "benchmarks.bench_sloc"),
    ("roofline", "benchmarks.roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced problem sizes (CI perf-smoke job)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args()
    rows = []
    for name, mod_name in BENCHES:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        mod = __import__(mod_name, fromlist=["bench"])
        kwargs = SMOKE_KWARGS.get(name, {}) if args.smoke else {}
        try:
            rows.extend(mod.bench(**kwargs))
            rows.append(f"_{name}_wall,{(time.time()-t0)*1e6:.0f},")
        except Exception as e:  # keep the harness going; record the failure
            rows.append(f"_{name}_FAILED,0,{type(e).__name__}:{e}")
            print(f"[bench] {name} failed: {e}", file=sys.stderr)
    emit(rows)
    if args.json:
        recs = []
        for r in rows:
            n, us, derived = r.split(",", 2)
            recs.append({"name": n, "us_per_call": float(us), "derived": derived})
        with open(args.json, "w") as f:
            json.dump(recs, f, indent=1)


if __name__ == "__main__":
    main()
