"""Shared benchmark helpers: timing + CSV rows (name,us_per_call,derived)."""
from __future__ import annotations

import time

import jax


def timeit(fn, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call (seconds), blocking on results."""
    for _ in range(warmup):
        jax.block_until_ready(fn()) if _is_jax(fn) else fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn()
        try:
            jax.block_until_ready(out)
        except Exception:
            pass
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _is_jax(fn):
    return True


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds*1e6:.1f},{derived}"


def emit(rows: list[str]):
    print("name,us_per_call,derived")
    for r in rows:
        print(r)
