"""Transitive Closure (paper Fig. 18): join/union/distinct fixed point."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.apps.graph import make_graph, tc_reference, transitive_closure
from repro.core import ICluster, IProperties, IWorker


def bench(n_vertices: int = 14, n_edges: int = 26):
    edges = make_graph(n_vertices, n_edges, seed=3)
    exp = tc_reference(edges)
    rows = []
    res = {}
    for mode in ("ignis", "spark"):
        w = IWorker(ICluster(IProperties({"ignis.mode": mode})), "python")
        tc = transitive_closure(w, edges)
        got = {(int(np.asarray(a)), int(np.asarray(b))) for a, b in tc.collect()}
        assert got == exp, (len(got), len(exp))
        t = timeit(lambda: transitive_closure(w, edges).count(), warmup=0, iters=2)
        res[mode] = t
        rows.append(row(f"tc_{mode}", t, f"closure_edges={len(exp)}"))
    rows.append(row("tc_speedup", 0.0,
                    f"ignis_vs_spark={res['spark']/res['ignis']:.2f}x"))
    return rows
