"""Stage fusion (DESIGN.md §5): a 6-op narrow chain evaluated with the stage
compiler (one jit dispatch per block, compiled once) vs. the unfused engine
(one Python-level block_fn dispatch per op per block) — the driver-roundtrip
overhead the paper measures against Spark, at the intra-stage scale.

Also demonstrates the compiled-plan cache: the second action over the same
lineage re-uses every compiled stage kernel (hits > 0, misses unchanged).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core import ICluster, IProperties, IWorker


def _pipeline(worker, data, blocks):
    return (
        worker.parallelize(data, blocks=blocks)
        .map(lambda x: x * 3 + 1)
        .filter(lambda x: x % 2 == 0)
        .map(lambda x: x // 2)
        .map(lambda x: x * x)
        .filter(lambda x: x % 5 != 0)
        .map(lambda x: x + 7)
    )


def _host_oracle(xs):
    out = []
    for x in xs:
        x = x * 3 + 1
        if x % 2 != 0:
            continue
        x = (x // 2) ** 2
        if x % 5 == 0:
            continue
        out.append(x + 7)
    return sorted(out)


def bench(n: int = 1 << 14, blocks: int = 16, iters: int = 5):
    data = np.arange(n, dtype=np.int64) % 1009
    fused_w = IWorker(ICluster(IProperties()), "python")
    unfused_w = IWorker(
        ICluster(IProperties({"ignis.fusion.enabled": "false"})), "python"
    )
    fused = _pipeline(fused_w, data, blocks)
    unfused = _pipeline(unfused_w, data, blocks)

    # correctness parity first (and warm both engines' compile caches)
    exp = _host_oracle(int(x) for x in data)
    assert sorted(int(x) for x in fused.collect()) == exp
    assert sorted(int(x) for x in unfused.collect()) == exp

    hits0 = fused_w.engine.stats["plan_cache_hits"]
    misses0 = fused_w.engine.stats["plan_cache_misses"]

    t_fused = timeit(lambda: fused.count(), warmup=1, iters=iters)
    t_unfused = timeit(lambda: unfused.count(), warmup=1, iters=iters)

    stats = fused_w.stage_stats()
    assert stats["plan_cache_hits"] > hits0, "second action must hit the plan cache"
    assert stats["plan_cache_misses"] == misses0, "same lineage must not recompile"

    rows = [
        row("fusion_6op_fused", t_fused, f"blocks={blocks} n={n}"),
        row("fusion_6op_unfused", t_unfused, f"blocks={blocks} n={n}"),
        # target=1.0: both arms run the same device-bound workload seconds
        # apart, so the ratio is machine-independent — fused must never be
        # slower than unfused (the tools/check_bench.py floor)
        row(
            "fusion_speedup",
            0.0,
            f"fused_vs_unfused={t_unfused / t_fused:.2f}x target=1.0 "
            f"plan_cache_hits={stats['plan_cache_hits']}",
        ),
    ]
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(bench())
