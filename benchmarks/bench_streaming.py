"""Streaming micro-batch ingestion under multi-tenant serving load
(docs/streaming.md, DESIGN.md §12).

Four simulated tenants, ≥1000 micro-batch jobs per timed arm (default
4 × 250), each batch a replayable ``TenantRequestSource`` slice folded
through a deterministic batch function:

  * **single** (the baseline): tenants run ONE AT A TIME — each stream
    pumps to exhaustion on the full mesh before the next starts. This is
    the "dedicated cluster per tenant" deployment the paper's unified
    runtime replaces: no sharing, no interference, total wall = sum of
    streams.
  * **multi**: all four pumps run CONCURRENTLY through one
    ``TenantFrontEnd`` — per-tenant gang groups (``worker.groups(4)``),
    one shared ``IJob``/scheduler/admission controller. Batch compute
    overlaps across tenants; the admission bound keeps per-tenant p99
    from collapsing.

Headline factors (interleaved per-iteration ratios, median — same
discipline as bench_groups; machine-load drift between separate timing
blocks skews a ratio of medians):

  * ``multi_vs_single``: throughput — gang-grouped multi-tenancy must beat
    (or on small hosts, match) the sequential baseline. MACHINE-AWARE
    target: 1.15 on ≥4-core hosts (batch compute genuinely overlaps),
    0.95 on 2-3 cores, 0.75 on single-core hosts — there, with zero
    spare cores, four time-sliced pumps cannot beat one and the row only
    bounds the cost of sharing (observed ~0.86x).
  * ``p99_headroom``: bounded interference — the multi-tenant per-batch
    p99 may not exceed ``allowed×`` the single-tenant p99 (allowed is
    8 on ≥4 cores, 16 below: admission keeps queues bounded, but small
    hosts serialize harder). Emitted as ``allowed·p99_single/p99_multi``
    so the floor is the fixed ``target=1.0``.

Counter gates (machine-independent, zero tolerance via check_bench.py):
the clean arms must run with ``batches_replayed=0 shed=0`` — a replay or a
shed on the fault-free path is a scheduler/admission regression regardless
of hardware. The recovery row then kills one micro-batch mid-stream and
must report EXACTLY ``faulted_batches_replayed=1`` with bit-identical
folded state (the exactly-once claim, perf-gated).

Needs 8 devices → re-executes itself in a subprocess with
``--xla_force_host_platform_device_count=8`` (flag must not leak).
"""
from __future__ import annotations

import os
import subprocess
import sys


def _child(tenants: int, batches: int, rows_per_batch: int, iters: int) -> list:
    import numpy as np

    from benchmarks.common import row
    from repro.core import ICluster, IProperties, IWorker, faults
    from repro.core.faults import FaultPlan
    from repro.streaming import (
        StreamContext, StreamTelemetry, TenantFrontEnd, TenantRequestSource)

    limit = batches * rows_per_batch
    props = {
        "ignis.executor.instances": "8",
        "ignis.stream.batch.rows": str(rows_per_batch),
        # let all four quotas be in flight at once — the global bound must
        # not serialize tenants the groups were meant to isolate
        "ignis.stream.max.inflight": str(4 * tenants),
    }
    w = IWorker(ICluster(IProperties(props)), "python")

    def batch_fn(rows):
        # deterministic, GIL-releasing compute: the folded state stays
        # exactly reproducible (bit-identical under replay) while the sin
        # reduction gives the scheduler real work to overlap across groups
        base = np.sum(rows.astype(np.int64), axis=0)
        x = np.sin(np.arange(200_000, dtype=np.float64)
                   * (1.0 + float(base[1] % 97) * 1e-3))
        return np.concatenate([base.astype(np.float64), [float(x.sum())]])

    def zeros():
        return np.zeros((3,), np.float64)

    def src(i):
        return TenantRequestSource(i, seed=17, limit=limit)

    def run_single():
        tel = StreamTelemetry()
        states = {}
        for i in range(tenants):
            sc = StreamContext(w, src(i), tenant=f"t{i}", batch_fn=batch_fn,
                               init_state=zeros(), telemetry=tel)
            states[f"t{i}"] = sc.run()
            sc.job.release()
        return states, tel

    def run_multi():
        fe = TenantFrontEnd(w, n_groups=tenants)
        for i in range(tenants):
            fe.admit(f"t{i}", src(i), batch_fn=batch_fn, init_state=zeros())
        states = fe.run()
        fe.job.release()
        return states, fe.telemetry, fe

    def p99(tel):
        snap = tel.snapshot()
        return max(t["latency_p99_ms"] for t in snap["tenants"].values())

    def totals(tel):
        snap = tel.snapshot()
        return snap["batches_replayed"], snap["shed"], snap["completed"]

    # correctness parity + compile/alloc warm-up for both arms: sequential
    # and gang-grouped pumps must fold identical per-tenant states
    s_states, _ = run_single()
    m_states, m_tel, _fe = run_multi()
    for t in s_states:
        assert (s_states[t] == m_states[t]).all(), t
    rep0, shed0, done0 = totals(m_tel)
    assert (rep0, shed0) == (0, 0), (rep0, shed0)
    assert done0 == tenants * batches, done0

    # INTERLEAVED timing (bench_groups discipline): arms alternate within
    # each iteration, the headline is the median of per-iteration ratios
    import time as _time

    ts, tm, ratios, p99s_s, p99s_m = [], [], [], [], []
    for _ in range(iters):
        t0 = _time.perf_counter()
        _, tel_s = run_single()
        t1 = _time.perf_counter()
        _, tel_m, _ = run_multi()
        t2 = _time.perf_counter()
        ts.append(t1 - t0)
        tm.append(t2 - t1)
        ratios.append((t1 - t0) / (t2 - t1))
        p99s_s.append(p99(tel_s))
        p99s_m.append(p99(tel_m))
    t_single = sorted(ts)[len(ts) // 2]
    t_multi = sorted(tm)[len(tm) // 2]
    speedup = sorted(ratios)[len(ratios) // 2]
    p99_s = sorted(p99s_s)[len(p99s_s) // 2]
    p99_m = sorted(p99s_m)[len(p99s_m) // 2]

    cores = os.cpu_count() or 1
    target = 1.15 if cores >= 4 else (0.95 if cores >= 2 else 0.75)
    allowed = 8.0 if cores >= 4 else 16.0
    headroom = allowed * p99_s / max(p99_m, 1e-9)
    n_jobs = tenants * batches

    # recovery arm: kill one micro-batch mid-stream; lineage replays it and
    # the folded state stays bit-identical with EXACTLY one counted replay
    plan = FaultPlan().fail_stream_batch(tenant="t1", batch=batches // 2)
    t0 = _time.perf_counter()
    with faults.inject(plan):
        f_states, f_tel, fe_f = run_multi()
    t_fault = _time.perf_counter() - t0
    for t in f_states:
        assert (f_states[t] == s_states[t]).all(), t
    f_rep, f_shed, _ = totals(f_tel)
    assert f_rep == plan.injections("stream.batch") == 1, f_rep
    assert fe_f.stream("t1").batches_replayed == 1

    return [
        row("stream_single", t_single,
            f"tenants={tenants} batches={n_jobs} rows={rows_per_batch} "
            f"sequential world=8"),
        row("stream_multi", t_multi,
            f"groups={tenants} inflight_bound={4 * tenants}"),
        row("stream_throughput", 0.0,
            f"multi_vs_single={speedup:.2f}x target={target:g} "
            f"batches_replayed={rep0} shed={shed0} jobs={n_jobs}"),
        row("stream_p99", 0.0,
            f"p99_headroom={headroom:.2f}x target=1.0 allowed={allowed:g} "
            f"p99_single_ms={p99_s:.2f} p99_multi_ms={p99_m:.2f}"),
        row("stream_recovery", t_fault,
            f"faulted_batches_replayed={f_rep} shed={f_shed} bitident=1"),
    ]


def bench(tenants: int = 4, batches: int = 250, rows_per_batch: int = 16,
          iters: int = 3) -> list:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root, env.get("PYTHONPATH", "")])
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child", str(tenants),
         str(batches), str(rows_per_batch), str(iters)],
        env=env, capture_output=True, text=True, timeout=1800, cwd=root,
    )
    if r.returncode != 0:
        raise RuntimeError(f"bench_streaming child failed:\n{r.stderr[-2000:]}")
    rows = [ln[len("ROW "):] for ln in r.stdout.splitlines()
            if ln.startswith("ROW ")]
    if not rows:
        raise RuntimeError(f"bench_streaming child emitted no rows:\n{r.stdout}")
    return rows


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        tenants, batches, rows_per_batch, iters = (int(x) for x in sys.argv[2:6])
        for r in _child(tenants, batches, rows_per_batch, iters):
            print(f"ROW {r}")
    else:
        from benchmarks.common import emit

        emit(bench())
