"""PageRank (paper Fig. 17): join/reduceByKey graph pattern on the dataflow
layer, ignis vs spark mode, validated against the host reference.

The iterative join/reduceByKey loop re-builds its lineage every iteration —
exactly the workload the shuffle capacity memory (DESIGN.md §6) targets —
so the derived column reports overflow retries, wide-stage recompiles and
capacity-memory hits alongside throughput."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.apps.graph import make_graph, pagerank, pagerank_reference
from repro.core import ICluster, IProperties, IWorker


def bench(n_vertices: int = 48, n_edges: int = 160, iters: int = 3):
    edges = make_graph(n_vertices, n_edges, seed=0)
    ref = pagerank_reference(edges, iters)
    rows = []
    res = {}
    for mode in ("ignis", "spark"):
        w = IWorker(ICluster(IProperties({"ignis.mode": mode})), "python")
        pr = pagerank(w, edges, iters)
        err = max(abs(pr[v] - ref[v]) for v in ref)
        assert err < 1e-3, err
        t = timeit(lambda: pagerank(w, edges, iters), warmup=0, iters=2)
        st = w.shuffle_stats()
        res[mode] = t
        rows.append(row(
            f"pagerank_{mode}", t,
            f"edges*iters/s={n_edges*iters/t:.0f} "
            f"retries={st['overflow_retries']} "
            f"recompiles={st['wide_plan_misses']} "
            f"mem_hits={st['capacity_memory_hits']}"))
    rows.append(row("pagerank_speedup", 0.0,
                    f"ignis_vs_spark={res['spark']/res['ignis']:.2f}x"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(bench())
